"""Heartbeat-based straggler/failure detection + mitigation policy.

Pure logic (injectable clock) so the policy is unit-testable without a
cluster.  In production each host posts a heartbeat after every step; the
coordinator runs ``observe`` and acts on the returned decisions:

  * ``straggler``  — step time > straggler_factor x rolling median: the
    launcher can re-balance (drop the host from the next elastic re-mesh) or
    just log; repeated stragglers escalate.
  * ``dead``       — no heartbeat for timeout_s: trigger checkpoint-restore
    onto the surviving mesh (ft/elastic.py).

This is intentionally mechanism-only: SCHEDULING reactions (evict/remesh/
continue) belong to the launcher, which the decisions parameterize.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

from repro.core import telemetry

log = telemetry.get_logger("heartbeat")


@dataclasses.dataclass
class Decision:
    host: str
    kind: str  # "ok" | "straggler" | "dead"
    detail: str = ""


class HeartbeatMonitor:
    def __init__(
        self,
        hosts: List[str],
        timeout_s: float = 120.0,
        straggler_factor: float = 2.0,
        window: int = 16,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.hosts = list(hosts)
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.last_beat: Dict[str, float] = {h: clock() for h in hosts}
        self.step_times: Dict[str, deque] = {h: deque(maxlen=window) for h in hosts}
        self.strikes: Dict[str, int] = defaultdict(int)

    def beat(self, host: str, step_time_s: Optional[float] = None):
        now = self.clock()
        self.last_beat[host] = now
        if step_time_s is not None:
            self.step_times[host].append(step_time_s)

    def observe(self) -> List[Decision]:
        """Evaluate the fleet; non-``ok`` decisions are logged (structured
        key=value lines, ``repro.telemetry.heartbeat`` namespace) and counted
        in the global metrics registry — the policy itself stays pure."""
        out = self._observe()
        for d in out:
            if d.kind == "dead":
                telemetry.metric_count("sz3_heartbeat_dead_total")
                log.error("host_dead", host=d.host, detail=d.detail)
            elif d.kind == "straggler":
                telemetry.metric_count("sz3_heartbeat_straggler_total")
                log.warning("host_straggler", host=d.host, detail=d.detail)
        return out

    def _observe(self) -> List[Decision]:
        now = self.clock()
        out: List[Decision] = []
        all_times = [t for h in self.hosts for t in self.step_times[h]]
        med = statistics.median(all_times) if all_times else None
        for h in self.hosts:
            if now - self.last_beat[h] > self.timeout_s:
                out.append(Decision(h, "dead", f"no heartbeat for {now - self.last_beat[h]:.0f}s"))
                continue
            if med and self.step_times[h]:
                mine = statistics.median(self.step_times[h])
                if mine > self.straggler_factor * med:
                    self.strikes[h] += 1
                    out.append(
                        Decision(
                            h,
                            "straggler",
                            f"median {mine:.2f}s vs fleet {med:.2f}s (strike {self.strikes[h]})",
                        )
                    )
                    continue
                self.strikes[h] = max(0, self.strikes[h] - 1)
            out.append(Decision(h, "ok"))
        return out

    def survivors(self) -> List[str]:
        now = self.clock()
        return [h for h in self.hosts if now - self.last_beat[h] <= self.timeout_s]
