from .checkpoint import CheckpointManager, CheckpointPolicy, LeafPolicy
from .elastic import make_elastic_mesh, replan, reshard_state, validate_divisibility
from .heartbeat import HeartbeatMonitor, Decision

__all__ = [
    "CheckpointManager", "CheckpointPolicy", "LeafPolicy",
    "make_elastic_mesh", "replan", "reshard_state", "validate_divisibility",
    "HeartbeatMonitor", "Decision",
]
