"""Error-bounded optimizer-moment compression via the jit codec facade.

Moments are encoded with ``core/jitmode``'s fixed tier blocked along the
last axis: per-block predictor contest (zero / Lorenzo-1 / mean), fixed
radius, mantissa-snapped per-block scales.  The value-range-relative bound
per block is ``scale/2`` (REL mode, eb ~= 0.2-0.8% of the block range at
int8).  Codes keep the parameter's shape (last dim padded to the block
size) so parameter PartitionSpecs apply unchanged; the side channels
(scale, tag, base) drop the last dim to ``ceil(last/BLOCK)`` blocks and
shard like the scale always has (``train/step.py`` maps any trailing
``codes``/``scale``/``tags``/``base`` path name to the parameter's spec).

Two bound domains:

* ``compress``/``decompress`` — linear values, per-block REL bound.  Right
  for the first moment (signed; its error is a small fraction of the
  block's gradient scale, which is the same regime as gradient noise).
* ``compress_nonneg``/``decompress_nonneg`` — the SECOND moment.  A block
  REL bound is catastrophic for ``v``: a small element in a block with a
  large absmax quantizes to code 0, its history is erased every step, and
  ``m/sqrt(v)`` blows up (the collapse is chaotic — whether a given run
  diverges depends on float noise).  Instead the value is compressed in
  the log2 domain, the classic SZ pointwise-relative (PW_REL) construction:
  an ABS bound of d on ``log2 v`` is the multiplicative bound
  ``v_hat/v in [2**-d, 2**d]``, so small ``v`` keeps its magnitude and the
  preconditioner stays bounded.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import jitmode
from ..core.jitmode import JitPolicy

BLOCK = 256
SCALE_FLOOR = jitmode.SCALE_FLOOR

DEFAULT_POLICY = JitPolicy(tier="int8", bs=BLOCK)


#: Floor for log-domain compression.  Must be comfortably NORMAL in f32 —
#: XLA-CPU flushes subnormal constants to zero and log2(0) = -inf poisons
#: the block stats.  sqrt(2**-100) ~= 9e-16 is far below Adam's eps.
NONNEG_FLOOR = float(2.0 ** -100)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "scale", "tags", "base"],
    meta_fields=["orig_last", "bits", "domain"],
)
@dataclasses.dataclass
class Compressed:
    codes: jnp.ndarray  # int8 (param shape, last dim padded) / uint8 packed
    scale: jnp.ndarray  # f32, (*lead, n_blocks)
    tags: jnp.ndarray  # uint8, (*lead, n_blocks) — winning predictor
    base: jnp.ndarray  # f32, (*lead, n_blocks) — predictor base value
    orig_last: int
    bits: int = 8
    domain: str = "linear"  # "linear" | "log2" (nonneg PW_REL)


def compress(x: jnp.ndarray, policy: Optional[JitPolicy] = None) -> Compressed:
    pol = policy or DEFAULT_POLICY
    x = x.astype(jnp.float32)
    if x.ndim == 0:
        x = x.reshape(1)
    codes, scale, tags, base, last = jitmode.encode_lastaxis(x, pol)
    flat_codes = codes.reshape(codes.shape[:-2] + (-1,))
    return Compressed(
        codes=flat_codes, scale=scale, tags=tags, base=base,
        orig_last=last, bits=pol.bits,
    )


def decompress(c: Compressed) -> jnp.ndarray:
    shp = c.codes.shape
    nb = c.scale.shape[-1]
    blocks = c.codes.reshape(shp[:-1] + (nb, shp[-1] // nb))
    x = jitmode.decode_lastaxis(
        blocks, c.scale, c.tags, c.base, c.orig_last, c.bits
    )
    if c.domain == "log2":
        x = jnp.exp2(x)
        # values that were at the floor (incl. exact zeros) decode back to 0
        x = jnp.where(x <= 2.0 * NONNEG_FLOOR, 0.0, x)
    return x


def compress_nonneg(
    x: jnp.ndarray, policy: Optional[JitPolicy] = None
) -> Compressed:
    """Pointwise-relative compression of a nonnegative array (log2 domain)."""
    u = jnp.log2(jnp.maximum(x.astype(jnp.float32), NONNEG_FLOOR))
    c = compress(u, policy)
    return dataclasses.replace(c, domain="log2")


def decompress_nonneg(c: Compressed) -> jnp.ndarray:
    return decompress(c)


def init_compressed(
    p: jnp.ndarray, policy: Optional[JitPolicy] = None, domain: str = "linear"
) -> Compressed:
    zeros = jnp.zeros(p.shape if p.ndim else (1,), jnp.float32)
    if domain == "log2":
        return compress_nonneg(zeros, policy)
    return compress(zeros, policy)


def compression_ratio(p: jnp.ndarray, policy: Optional[JitPolicy] = None) -> float:
    """Memory saving vs f32 moments."""
    c = init_compressed(p, policy)
    packed = c.codes.size + c.scale.size * 4 + c.tags.size + c.base.size * 4
    return (p.size * 4) / packed
