"""Error-bounded 8-bit optimizer-moment compression (paper quantizer, fixed
radius 127, per-block scales along the last axis).

The value-range-relative error bound per block is scale/2 = absmax/254 —
i.e. the paper's REL mode with eb ~= 0.2%.  Codes keep the parameter's shape
(so parameter PartitionSpecs apply unchanged); scales drop the last dim to
ceil(last/BLOCK) blocks.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256
SCALE_FLOOR = 1e-12


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "scale"],
    meta_fields=["orig_last"],
)
@dataclasses.dataclass
class Compressed:
    codes: jnp.ndarray  # int8, shape = param shape (last dim padded)
    scale: jnp.ndarray  # f32, (*lead, n_blocks)
    orig_last: int


def compress(x: jnp.ndarray) -> Compressed:
    x = x.astype(jnp.float32)
    if x.ndim == 0:
        x = x.reshape(1)
    last = x.shape[-1]
    pad = (-last) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nb = xp.shape[-1] // BLOCK
    blocks = xp.reshape(xp.shape[:-1] + (nb, BLOCK))
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.maximum(absmax / 127.0, SCALE_FLOOR)
    q = jnp.clip(jnp.rint(blocks / scale[..., None]), -127, 127).astype(jnp.int8)
    return Compressed(codes=q.reshape(xp.shape), scale=scale, orig_last=last)


def decompress(c: Compressed) -> jnp.ndarray:
    shp = c.codes.shape
    nb = shp[-1] // BLOCK
    blocks = c.codes.reshape(shp[:-1] + (nb, BLOCK)).astype(jnp.float32)
    x = blocks * c.scale[..., None]
    return x.reshape(shp)[..., : c.orig_last]


def init_compressed(p: jnp.ndarray) -> Compressed:
    return compress(jnp.zeros(p.shape if p.ndim else (1,), jnp.float32))


def compression_ratio(p: jnp.ndarray) -> float:
    """Memory saving vs f32 moments."""
    c = init_compressed(p)
    return (p.size * 4) / (c.codes.size + c.scale.size * 4)
