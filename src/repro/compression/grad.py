"""Error-bounded gradient compression for the data-parallel reduction.

Schedule (per train step, inside the dp-manual shard_map region):

  1. flatten the grad tree to one f32 vector, cast bf16;
  2. psum_scatter over the DP axes (ring reduce-scatter, bf16);
  3. add the persistent error-feedback residual, quantize the local shard with
     the paper's linear-scaling quantizer at fixed radius (int8 or packed
     int4, per-block scales), update the residual (error feedback makes the
     scheme unbiased over time — the quantization error is *carried*, i.e.
     exactly SZ's error-bound contract applied temporally);
  4. all_gather the codes (+ scales), dequantize, unflatten.

Collective bytes per device: ~2N (RS bf16) + N/ratio (AG codes), vs ~4N for a
bf16 all-reduce — a 1.33x (int8) / 1.6x (int4) cut of the dominant DP
collective term (EXPERIMENTS.md §Perf records the measured HLO deltas).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

BLOCK = 512
SCALE_FLOOR = 1e-12


def _flatten_tree(tree) -> Tuple[jnp.ndarray, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    meta = (treedef, [(l.shape, l.dtype) for l in leaves])
    return flat, meta


def _unflatten_tree(flat, meta):
    treedef, shapes = meta
    out, pos = [], 0
    for shp, dt in shapes:
        n = 1
        for s in shp:
            n *= s
        out.append(flat[pos : pos + n].reshape(shp).astype(jnp.float32))
        pos += n
    return jax.tree.unflatten(treedef, out)


def quantize_shard(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric quantization; returns (codes int8, scales f32)."""
    radius = 127 if bits == 8 else 7
    pad = (-x.shape[0]) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(xp), axis=-1)
    scale = jnp.maximum(absmax / radius, SCALE_FLOOR)
    q = jnp.clip(jnp.rint(xp / scale[:, None]), -radius, radius).astype(jnp.int8)
    if bits == 4:  # pack two nibbles per byte
        q = q.reshape(-1, BLOCK // 2, 2)
        packed = (q[..., 0].astype(jnp.uint8) & 0xF) | (
            (q[..., 1].astype(jnp.uint8) & 0xF) << 4
        )
        return packed.astype(jnp.int8).reshape(-1), scale
    return q.reshape(-1), scale


def dequantize_shard(codes, scale, n: int, bits: int) -> jnp.ndarray:
    if bits == 4:
        b = codes.astype(jnp.uint8)
        lo = (b & 0xF).astype(jnp.int8)
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = (b >> 4).astype(jnp.int8)
        hi = jnp.where(hi > 7, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(-1, BLOCK)
    else:
        q = codes.reshape(-1, BLOCK)
    x = q.astype(jnp.float32) * scale[:, None]
    return x.reshape(-1)[:n]


def compressed_reduce_flat(
    flat: jnp.ndarray,  # per-replica partial grad vector (local view)
    feedback: jnp.ndarray,  # local error-feedback shard, (ceil(N/dp),)
    dp_axes: Sequence[str],
    bits: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside a dp-manual shard_map: returns (reduced flat vector, new feedback)."""
    axes = tuple(dp_axes)
    dp = 1
    for a in axes:
        dp *= jax.lax.axis_size(a)
    n = flat.shape[0]
    pad = (-n) % dp
    fp = jnp.pad(flat, (0, pad)).astype(jnp.bfloat16)
    shard = jax.lax.psum_scatter(fp, axes, scatter_dimension=0, tiled=True)
    shard = shard.astype(jnp.float32) / dp + feedback
    codes, scale = quantize_shard(shard, bits)
    deq_local = dequantize_shard(codes, scale, shard.shape[0], bits)
    new_feedback = shard - deq_local
    codes_g = jax.lax.all_gather(codes, axes, tiled=True)
    scale_g = jax.lax.all_gather(scale, axes, tiled=True)
    out = dequantize_shard(codes_g, scale_g, n + pad, bits)[:n]
    return out, new_feedback


def init_feedback(params, dp: int) -> jnp.ndarray:
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    n_pad = n + ((-n) % dp)
    return jnp.zeros((n_pad,), jnp.float32)


def compressed_reduce_tree(grads, feedback, dp_axes, bits):
    flat, meta = _flatten_tree(grads)
    out, fb = compressed_reduce_flat(flat, feedback, dp_axes, bits)
    return _unflatten_tree(out, meta), fb
