"""Error-bounded gradient compression for the data-parallel reduction.

Schedule (per train step, inside a dp-manual shard_map region):

  1. flatten the grad tree to one f32 vector, cast bf16;
  2. psum_scatter over the DP axes (ring reduce-scatter, bf16);
  3. add the persistent error-feedback residual, encode the local shard with
     the jit codec facade (``core/jitmode``): per-block predictor contest
     (zero / Lorenzo-1 / mean) at fixed radius, int8 or packed int4 codes,
     per-block scales snapped to the 3-bit-mantissa grid (exact decode
     products, so jit/eager/host decode bit-identically — core/jitmode).
     The residual update (error feedback)
     makes the scheme unbiased over time — the quantization error is
     *carried*, i.e. exactly SZ's error-bound contract applied temporally;
  4. all_gather the codes + side channels (scale/tag/base per block),
     decode, unflatten to the recorded per-leaf dtypes.

Collective bytes per device: ~2N (RS bf16) + N*bits/8 + side channels (AG),
vs ~4N for a bf16 all-reduce — a >=1.3x (int8) / ~1.6x (int4) cut of the
dominant DP collective term (:func:`collective_bytes` is the accounting the
bench rows and regression gates use).

The legacy ``quantize_shard``/``dequantize_shard`` API is kept as the
zero-predictor special case of the facade (same wire layout as the pre-PR
hand-rolled quantizer, now sharing one code path with everything else).
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from ..core import jitmode
from ..core.jitmode import JitPolicy

BLOCK = 512
SCALE_FLOOR = jitmode.SCALE_FLOOR

PolicyLike = Union[int, str, JitPolicy]


def as_policy(policy: PolicyLike) -> JitPolicy:
    """Accept legacy bit counts (8/4), spec strings, or JitPolicy."""
    if isinstance(policy, JitPolicy):
        return policy
    if isinstance(policy, str):
        return JitPolicy.parse(policy)
    if policy in (8, 4):
        return JitPolicy(tier=f"int{policy}", bs=BLOCK)
    raise ValueError(f"bad gradient compression policy {policy!r}")


def _flatten_tree(tree) -> Tuple[jnp.ndarray, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    meta = (treedef, [(l.shape, l.dtype) for l in leaves])
    return flat, meta


def _unflatten_tree(flat, meta):
    treedef, shapes = meta
    out, pos = [], 0
    for shp, dt in shapes:
        n = 1
        for s in shp:
            n *= s
        # restore the RECORDED leaf dtype: force-casting to f32 here would
        # silently widen bf16 params' gradients after the reduction
        out.append(flat[pos : pos + n].reshape(shp).astype(dt))
        pos += n
    return jax.tree.unflatten(treedef, out)


def _zero_policy(bits: int) -> JitPolicy:
    return JitPolicy(tier=f"int{bits}", bs=BLOCK, predictors=("zero",))


def quantize_shard(x: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric quantization; returns (codes int8, scales f32).

    Zero-predictor fixed tier of the jit facade: flat codes, per-block
    mantissa-snapped scales, bound scale/2 per block (plus f32 slack).
    """
    c = jitmode.encode(x, _zero_policy(bits))
    return c.codes.reshape(-1), c.scale


def dequantize_shard(codes, scale, n: int, bits: int) -> jnp.ndarray:
    nb = scale.shape[0]
    per = BLOCK // 2 if bits == 4 else BLOCK
    zeros = jnp.zeros((nb,), jnp.uint8)
    xb = jitmode.decode_blocks(
        codes.reshape(nb, per), scale, zeros, zeros.astype(jnp.float32), bits
    )
    return xb.reshape(-1)[:n]


def compressed_reduce_flat(
    flat: jnp.ndarray,  # per-replica partial grad vector (local view)
    feedback: jnp.ndarray,  # local error-feedback shard, (ceil(N/dp),)
    dp_axes: Sequence[str],
    policy: PolicyLike,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside a dp-manual shard_map: returns (reduced flat vector, new feedback)."""
    pol = as_policy(policy)
    axes = tuple(dp_axes)
    dp = 1
    for a in axes:
        # psum of a python literal folds to the axis size (no collective);
        # jax.lax.axis_size only exists on newer jax
        dp *= int(jax.lax.psum(1, a))
    n = flat.shape[0]
    pad = (-n) % dp
    fp = jnp.pad(flat, (0, pad)).astype(jnp.bfloat16)
    shard = jax.lax.psum_scatter(fp, axes, scatter_dimension=0, tiled=True)
    shard = shard.astype(jnp.float32) / dp + feedback
    m = shard.shape[0]
    c = jitmode.encode(shard, pol)
    new_feedback = shard - jitmode.decode(c)
    gathered = jax.tree.map(
        lambda a: jax.lax.all_gather(a, axes, tiled=True), c
    )
    # each shard's blocks carry their own tail padding (m need not divide
    # the block size), so crop per shard before re-flattening
    xb = jitmode.decode_blocks(
        gathered.codes, gathered.scale, gathered.tags, gathered.base, pol.bits
    )
    out = xb.reshape(dp, -1)[:, :m].reshape(-1)[:n]
    return out, new_feedback


def init_feedback(params, dp: int) -> jnp.ndarray:
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    n_pad = n + ((-n) % dp)
    return jnp.zeros((n_pad,), jnp.float32)


def compressed_reduce_tree(grads, feedback, dp_axes, policy: PolicyLike):
    flat, meta = _flatten_tree(grads)
    out, fb = compressed_reduce_flat(flat, feedback, dp_axes, policy)
    return _unflatten_tree(out, meta), fb


def collective_bytes(n: int, dp: int, policy: PolicyLike) -> Dict[str, float]:
    """Per-device DP-collective byte model for one reduction of n floats.

    Baseline: bf16 all-reduce ~= reduce-scatter + all-gather at 2 B/elem
    => 4n.  Compressed: bf16 reduce-scatter (2n) + code all-gather
    (n*bits/8 plus scale/tag/base side channels per block).
    """
    pol = as_policy(policy)
    n_pad = n + ((-n) % max(dp, 1))
    m = n_pad // max(dp, 1)
    nb = -(-m // pol.bs)
    code_bytes_shard = nb * pol.bs * pol.bits // 8 + nb * (4 + 1 + 4)
    rs = 2.0 * n_pad
    ag = float(dp * code_bytes_shard)
    baseline = 4.0 * n_pad
    return {
        "baseline_bf16_allreduce": baseline,
        "rs_bytes": rs,
        "ag_bytes": ag,
        "compressed_total": rs + ag,
        "cut_vs_bf16_allreduce": baseline / (rs + ag),
    }
