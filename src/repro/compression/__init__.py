# In-loop (jittable) integrations of the paper's quantizer module:
# gradient all-reduce compression, optimizer-moment compression, KV-cache
# quantization.  Host-side full-pipeline compression lives in repro.core;
# checkpoint integration in repro.ft.
from . import grad, kvcache, opt_state  # noqa: F401
