"""KV-cache quantization policy (the paper's quantizer on the serving path).

Per-token-per-head symmetric int8 (radius 127): each appended token's (hd,)
vector is quantized against its own absmax — the linear-scaling quantizer
with a per-element bound of scale/2.  ``lm._decode_attn`` applies this inline
during decode; this module provides the same policy for bulk prefill
quantization (filling a cache from prompt KV) plus quality metrics for tests
and benchmarks.  The fused dequant-matmul Pallas kernel lives in
repro/kernels/kvquant.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

SCALE_FLOOR = 1e-8


def quantize_tokens(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., hd) -> (int8 codes (..., hd), scales (...))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, SCALE_FLOOR)
    q = jnp.clip(jnp.rint(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_tokens(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[..., None]


def cache_bytes(seq: int, n_kv: int, hd: int, dtype: str) -> int:
    """Per-layer per-sequence cache bytes (K+V)."""
    if dtype == "int8":
        return 2 * seq * n_kv * (hd + 4)
    itemsize = 2 if dtype in ("bf16", "bfloat16") else 4
    return 2 * seq * n_kv * hd * itemsize


def quantization_snr_db(x: jnp.ndarray) -> float:
    q, s = quantize_tokens(x)
    err = dequantize_tokens(q, s) - x.astype(jnp.float32)
    p_sig = jnp.mean(x.astype(jnp.float32) ** 2)
    p_err = jnp.maximum(jnp.mean(err**2), 1e-30)
    return float(10.0 * jnp.log10(p_sig / p_err))


# -- jit-tier prefill compression (core/jitmode facade) ----------------------
#
# Bulk prompt-KV quantization through the same per-block predictor contest
# the gradient and moment paths use: each token's (hd,) vector is one block
# (bs = hd padded), so the per-token bound contract matches quantize_tokens
# but head vectors with structure (near-constant heads, smooth RoPE bands)
# get the Lorenzo/mean predictors' tighter scales for free.

import dataclasses as _dataclasses
from functools import partial as _partial

from ..core import jitmode as _jitmode
from ..core.jitmode import JitPolicy


@_partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "scale", "tags", "base"],
    meta_fields=["orig_hd", "bits"],
)
@_dataclasses.dataclass
class PrefillCodes:
    codes: jnp.ndarray  # (..., nb, bs) int8 / packed uint8
    scale: jnp.ndarray  # (..., nb) f32
    tags: jnp.ndarray  # (..., nb) uint8
    base: jnp.ndarray  # (..., nb) f32
    orig_hd: int
    bits: int

    def bound(self) -> jnp.ndarray:
        """Per-block bound, same contract as BlockCodes.bound()."""
        mag = _jitmode._sel_magnitude(self.codes, self.tags, self.bits)
        slack = (jnp.abs(self.base) + self.scale * mag) * jnp.float32(2.0**-22)
        return self.scale * 0.5 + slack


def prefill_policy(hd: int, bits: int = 8) -> JitPolicy:
    """One block per token vector (hd rounded up to even for int4)."""
    bs = hd + (hd % 2)
    return JitPolicy(tier=f"int{bits}", bs=bs)


def quantize_prefill(x: jnp.ndarray, policy: Optional[JitPolicy] = None) -> PrefillCodes:
    """x: (..., hd) bulk prompt KV -> per-token jit-tier codes."""
    pol = policy or prefill_policy(x.shape[-1])
    codes, scale, tags, base, last = _jitmode.encode_lastaxis(x, pol)
    return PrefillCodes(
        codes=codes, scale=scale, tags=tags, base=base,
        orig_hd=last, bits=pol.bits,
    )


def dequantize_prefill(c: PrefillCodes) -> jnp.ndarray:
    return _jitmode.decode_lastaxis(
        c.codes, c.scale, c.tags, c.base, c.orig_hd, c.bits
    )
