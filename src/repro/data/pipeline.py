"""Deterministic, stateless, sharded synthetic data pipeline.

``batch_at(step)`` is a pure function of (seed, step) — resumability and
elasticity fall out for free: after restore, training continues from step N
with bit-identical batches, on ANY dp width (the global batch is materialized
per-host by slicing, so re-sharding never changes the data order).  Real
deployments swap their tokenized corpus behind the same interface; everything
upstream (train loop, checkpoints, FT) only sees ``batch_at``.

The synthetic stream is a Zipf-ish token distribution with local n-gram
correlation so losses are non-trivial and compressible state appears in the
optimizer (exercises the lossy checkpoint path honestly).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 1234


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _tokens(self, step: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng((int(c.seed) + int(step) * 0x9E3779B97F4A7C15) % (1 << 64))
        # zipf-ish marginal + markov smoothing for local structure
        base = rng.zipf(1.3, size=(c.global_batch, c.seq)).astype(np.int64)
        tok = base % c.vocab
        shift = np.roll(tok, 1, axis=1)
        mix = rng.random((c.global_batch, c.seq)) < 0.3
        tok = np.where(mix, (shift + 7) % c.vocab, tok)
        return tok.astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        tok = self._tokens(step)
        labels = np.roll(tok, -1, axis=1)
        labels[:, -1] = -1  # no target for the last position
        return {"tokens": tok, "labels": labels}


class SyntheticEncDec(SyntheticLM):
    def __init__(self, cfg: DataConfig, enc_seq: int, d_model: int):
        super().__init__(cfg)
        self.enc_seq = enc_seq
        self.d_model = d_model

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b = super().batch_at(step)
        rng = np.random.default_rng(self.cfg.seed * 31 + step)
        b["enc_frames"] = rng.standard_normal(
            (self.cfg.global_batch, self.enc_seq, self.d_model), np.float32
        )
        return b


class SyntheticVLM(SyntheticLM):
    def __init__(self, cfg: DataConfig, d_model: int):
        super().__init__(cfg)
        self.d_model = d_model

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b = super().batch_at(step)
        rng = np.random.default_rng(self.cfg.seed * 17 + step)
        b["embeds"] = rng.standard_normal(
            (self.cfg.global_batch, self.cfg.seq, self.d_model), np.float32
        )
        del b["tokens"]
        return b


def make_pipeline(cfg: ModelConfig, seq: int, global_batch: int, seed: int = 1234):
    dc = DataConfig(vocab=cfg.vocab, seq=seq, global_batch=global_batch, seed=seed)
    if cfg.family == "encdec":
        return SyntheticEncDec(dc, cfg.enc_seq, cfg.d_model)
    if cfg.family == "vlm":
        return SyntheticVLM(dc, cfg.d_model)
    return SyntheticLM(dc)
