from .pipeline import DataConfig, SyntheticLM, SyntheticEncDec, SyntheticVLM, make_pipeline

__all__ = ["DataConfig", "SyntheticLM", "SyntheticEncDec", "SyntheticVLM", "make_pipeline"]
