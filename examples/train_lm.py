"""End-to-end training driver: train an LM with the full production stack —
sharded train step, microbatching, SZ3-compressed checkpoints, deterministic
resumable data, straggler monitoring, optional error-bounded gradient
compression and 8-bit optimizer moments.

    # ~20M-param run that fits a CPU smoke (default):
    PYTHONPATH=src python examples/train_lm.py --steps 50

    # ~100M-class run (the deliverable config; give it time or a TPU):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # resume after a crash:
    PYTHONPATH=src python examples/train_lm.py --steps 50   # re-run: auto-resumes
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import make_pipeline
from repro.ft import CheckpointManager, HeartbeatMonitor
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig
from repro.parallel import ParallelPlan
from repro.train.step import init_train_state, make_train_step

PRESETS = {
    "smoke": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=704, vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    ap.add_argument("--compress-moments", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = ModelConfig(
        name=f"train-lm-{args.preset}", family="dense", mlp_act="swiglu",
        dtype="float32", **PRESETS[args.preset],
    )
    n_params = cfg.n_flop_params()
    print(f"model: {cfg.name}  ~{n_params/1e6:.0f}M params")

    plan = ParallelPlan(
        microbatches=args.microbatches,
        grad_compress_bits=args.grad_compress_bits,
        remat="full",
    )
    opt = AdamWConfig(lr=args.lr, compress_moments=args.compress_moments)
    pipe = make_pipeline(cfg, seq=args.seq, global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = HeartbeatMonitor(["host0"], timeout_s=600)

    state = init_train_state(jax.random.PRNGKey(0), cfg, plan, opt)
    start = 0
    if mgr.list_steps():
        template = jax.tree.map(np.asarray, state)
        host, extra = mgr.restore(template)
        state = jax.tree.map(jnp.asarray, host)
        start = int(extra.get("next_step", 0))
        print(f"resumed from checkpoint at step {start}")

    step_fn = jax.jit(make_train_step(cfg, plan, opt, total_steps=args.steps), donate_argnums=0)

    t_last = time.perf_counter()
    for k in range(start, args.steps):
        batch = {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k).items()}
        state, metrics = step_fn(state, batch)
        dt = time.perf_counter() - t_last
        t_last = time.perf_counter()
        mon.beat("host0", dt)
        if k % 5 == 0 or k == args.steps - 1:
            tok_s = args.batch * args.seq / dt
            print(
                f"step {k:4d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} {tok_s:,.0f} tok/s"
            )
        if (k + 1) % args.ckpt_every == 0:
            mgr.save(k + 1, state, extra={"next_step": k + 1})
    mgr.wait()
    decisions = mon.observe()
    print("heartbeat:", [(d.host, d.kind) for d in decisions])
    print("checkpoints:", mgr.list_steps())


if __name__ == "__main__":
    main()
