"""Quickstart: compose SZ3 pipelines and compress a scientific field.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    SZ3Compressor,
    decompress,
    metrics,
    predictors,
    quantizers,
    encoders,
    lossless,
    sz3_interp,
    sz3_lr,
    sz3_truncation,
)

# a turbulence-like 3-D field
rng = np.random.default_rng(0)
x = rng.normal(size=(64, 96, 96))
for ax in range(3):
    x = np.cumsum(x, axis=ax) / np.sqrt(x.shape[ax])
x = x.astype(np.float32)

conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)

print(f"{'pipeline':24s} {'ratio':>8s} {'bitrate':>8s} {'psnr':>8s} {'max_err_ok':>10s}")
for name, comp in [
    ("SZ3-LR (paper §6.2)", sz3_lr()),
    ("SZ3-Interp", sz3_interp()),
    ("SZ3-Truncation", sz3_truncation(2)),
]:
    res = comp.compress(x, conf)
    xhat = decompress(res.blob)
    rng_v = float(x.max() - x.min())
    ok = metrics.max_abs_error(x, xhat) <= 1e-3 * rng_v * 1.001 or "Trunc" in name
    print(
        f"{name:24s} {res.ratio:8.2f} {metrics.bit_rate(x, len(res.blob)):8.3f} "
        f"{metrics.psnr(x, xhat):8.2f} {str(bool(ok)):>10s}"
    )

# the composability thesis: build YOUR OWN pipeline in one expression
custom = SZ3Compressor(
    predictor=predictors.LorenzoPredictor(order=2),
    quantizer=quantizers.UnpredAwareQuantizer(),
    encoder=encoders.FixedHuffmanEncoder(),
    lossless=lossless.Zstd(level=8),
)
res = custom.compress(x, conf)
print(f"{'custom (2nd-order+unpred)':24s} {res.ratio:8.2f}")
