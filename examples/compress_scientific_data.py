"""Scenario example: the paper's two case studies end-to-end.

1. GAMESS ERI (paper §4): SZ-Pastri vs SZ3-Pastri — the unpred-aware
   quantizer + lossless stage improvement at eb=1e-10.
2. APS ptychography (paper §5): the adaptive pipeline switching at eb=0.5,
   lossless on integer photon counts.

    PYTHONPATH=src python examples/compress_scientific_data.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks import datasets
from repro.core import (
    CompressionConfig,
    decompress,
    metrics,
    sz3_aps,
    sz3_pastri,
    sz_pastri,
)

print("=== GAMESS ERI (paper §4, abs eb = 1e-10) ===")
eri = datasets.gamess_eri(n_blocks=2000)
for name, comp in [("SZ-Pastri", sz_pastri(96)), ("SZ3-Pastri", sz3_pastri(96))]:
    res = comp.compress(eri, CompressionConfig(eb=1e-10))
    xhat = decompress(res.blob)
    print(
        f"  {name:12s} ratio={res.ratio:6.2f} "
        f"max_err={metrics.max_abs_error(eri, xhat):.2e}"
    )

print("=== APS ptychography (paper §5, adaptive) ===")
img = datasets.aps_ptycho(frames=96, h=48, w=48)
for eb in [0.25, 4.0]:
    res = sz3_aps().compress(img, CompressionConfig(eb=eb))
    xhat = decompress(res.blob)
    lossless = bool(np.array_equal(xhat, img))
    print(
        f"  eb={eb:5.2f} ratio={res.ratio:6.2f} "
        f"psnr={'inf (lossless)' if lossless else f'{metrics.psnr(img, xhat):.1f}'}"
    )
