"""Serving driver: batched decode with an (optionally int8-quantized) KV
cache — the paper's quantizer module on the inference path.

    PYTHONPATH=src python examples/serve_lm.py --kv int8 --tokens 32
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro import models
from repro.compression.kvcache import cache_bytes
from repro.parallel import ParallelPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--kv", default="bf16", choices=["bf16", "int8"])
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    plan = ParallelPlan(kv_cache_dtype=args.kv)
    params = models.init_params(jax.random.PRNGKey(0), cfg, plan)
    B = args.batch
    max_len = args.tokens + 8

    enc_frames = None
    if cfg.family == "encdec":
        enc_frames = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.enc_seq, cfg.d_model), cfg.param_dtype
        )
    cache = models.init_cache(params, cfg, plan, B, max_len, enc_frames=enc_frames)

    if cfg.n_kv_heads:
        full_cfg = configs.get(args.arch)
        b_bf16 = cache_bytes(32768, full_cfg.n_kv_heads, full_cfg.hd, "bf16")
        b_int8 = cache_bytes(32768, full_cfg.n_kv_heads, full_cfg.hd, "int8")
        print(
            f"[{full_cfg.name}] 32k-cache bytes/layer/seq: bf16={b_bf16/1e6:.1f}MB "
            f"int8={b_int8/1e6:.1f}MB ({b_bf16/b_int8:.2f}x saving)"
        )

    step = jax.jit(lambda p, c, t: models.decode_step(p, c, t, cfg, plan), donate_argnums=1)
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    seqs = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        seqs.append(tok)
    dt = time.perf_counter() - t0
    out = np.concatenate([np.asarray(t) for t in seqs], axis=1)
    print(f"decoded {args.tokens} tokens x {B} seqs in {dt:.2f}s "
          f"({args.tokens*B/dt:.1f} tok/s, kv={args.kv})")
    for i in range(min(2, B)):
        print(f"  seq{i}: {out[i][:16].tolist()}...")


if __name__ == "__main__":
    main()
